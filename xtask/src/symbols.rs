//! Module-aware symbol table over a source tree.
//!
//! Each `.rs` file under `rust/src` is lexed, stripped of comments, parsed
//! (see [`crate::parse`]), test-masked, and annotated with its module path
//! and its allocation-allowlist comments. The table is the shared substrate
//! the analyze rules and the call graph are built on, so every rule sees
//! the same token indices, masks, and item ranges.

use crate::lexer::{lex, Tok, Token};
use crate::parse::{parse, ParsedFile};
use crate::rules::test_mask;

/// One analyzed source file.
pub struct SourceFile {
    /// Path relative to the source root, `/`-separated (`piso/stepper.rs`).
    pub path: String,
    /// Module path derived from the file path (`piso/stepper.rs` →
    /// `["piso", "stepper"]`; `lib.rs`/`main.rs` → `[]`; `fvm/mod.rs` →
    /// `["fvm"]`).
    pub module: Vec<String>,
    /// Comment-free token stream (what the parser and rules index into).
    pub code: Vec<Token>,
    /// Per-token test mask (`true` = inside a `#[test]`/`#[cfg(test)]` item).
    pub test: Vec<bool>,
    /// Merged comment runs as `(first line, last line, mentions "ALLOC:")`,
    /// mirroring the SAFETY-run logic in the lint pass: contiguous `//`
    /// lines form one logical comment.
    pub comments: Vec<(usize, usize, bool)>,
    pub parsed: ParsedFile,
}

impl SourceFile {
    /// Whether an `// ALLOC:` justification run ends within the 3 lines
    /// above `line` (or on the line itself, for trailing comments) —
    /// the same proximity window the SAFETY rule uses.
    pub fn alloc_justified(&self, line: usize) -> bool {
        self.comments
            .iter()
            .any(|&(start, end, has_alloc)| has_alloc && end + 3 >= line && start <= line)
    }
}

/// All analyzed files, sorted by path for deterministic iteration.
pub struct SymbolTable {
    pub files: Vec<SourceFile>,
}

impl SymbolTable {
    /// Build from `(relative path, source text)` pairs.
    pub fn build(mut sources: Vec<(String, String)>) -> SymbolTable {
        sources.sort_by(|a, b| a.0.cmp(&b.0));
        let files = sources
            .into_iter()
            .map(|(path, src)| {
                let tokens = lex(&src);
                let mut comments: Vec<(usize, usize, bool)> = Vec::new();
                for t in &tokens {
                    if let Tok::Comment(text) = &t.tok {
                        let alloc = text.contains("ALLOC:");
                        match comments.last_mut() {
                            Some((_, end, has_alloc)) if t.line <= *end + 1 => {
                                *end = t.end_line.max(*end);
                                *has_alloc |= alloc;
                            }
                            _ => comments.push((t.line, t.end_line, alloc)),
                        }
                    }
                }
                let code: Vec<Token> =
                    tokens.into_iter().filter(|t| !matches!(t.tok, Tok::Comment(_))).collect();
                let test = test_mask(&code);
                let parsed = parse(&code);
                let module = module_path(&path);
                SourceFile { path, module, code, test, comments, parsed }
            })
            .collect();
        SymbolTable { files }
    }

    pub fn file(&self, path: &str) -> Option<&SourceFile> {
        self.files.iter().find(|f| f.path == path)
    }
}

/// Derive the module path from a file path relative to the source root.
fn module_path(path: &str) -> Vec<String> {
    let mut parts: Vec<String> = path
        .trim_end_matches(".rs")
        .split('/')
        .map(str::to_string)
        .collect();
    if parts.last().map(String::as_str) == Some("mod") {
        parts.pop();
    }
    if parts.len() == 1 && matches!(parts[0].as_str(), "lib" | "main") {
        parts.pop();
    }
    parts
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn module_paths_follow_file_layout() {
        assert_eq!(module_path("lib.rs"), Vec::<String>::new());
        assert_eq!(module_path("main.rs"), Vec::<String>::new());
        assert_eq!(module_path("fvm/mod.rs"), vec!["fvm"]);
        assert_eq!(module_path("piso/stepper.rs"), vec!["piso", "stepper"]);
    }

    #[test]
    fn build_wires_masks_and_parse_together() {
        let src = "pub fn shipped(v: &[f64]) -> f64 { v[0] }\n\
                   #[cfg(test)]\nmod tests {\n  #[test]\n  fn t() { shipped(&[1.0]); }\n}"
            .to_string();
        let table = SymbolTable::build(vec![("linsolve/cg.rs".to_string(), src)]);
        let f = table.file("linsolve/cg.rs").expect("file present");
        assert_eq!(f.module, vec!["linsolve", "cg"]);
        // both the shipped fn and the test fn parse; masks tell them apart
        assert_eq!(f.parsed.fns.len(), 2);
        let shipped = &f.parsed.fns[0];
        let test_fn = &f.parsed.fns[1];
        let (s, _) = shipped.body.expect("shipped body");
        let (t, _) = test_fn.body.expect("test body");
        assert!(!f.test[s]);
        assert!(f.test[t]);
    }

    #[test]
    fn alloc_comment_runs_are_tracked() {
        let src = "fn k(n: usize) {\n\
                   // ALLOC: scratch sized once per solve, reused across iterations\n\
                   let v = vec![0.0; n];\n\
                   let w = vec![1.0; n];\n}"
            .to_string();
        let table = SymbolTable::build(vec![("linsolve/cg.rs".to_string(), src)]);
        let f = table.file("linsolve/cg.rs").expect("file present");
        assert!(f.alloc_justified(3));
        assert!(f.alloc_justified(4), "the 3-line window extends past one line");
        assert!(!f.alloc_justified(30));
    }

    #[test]
    fn files_sort_deterministically() {
        let table = SymbolTable::build(vec![
            ("z.rs".to_string(), String::new()),
            ("a.rs".to_string(), String::new()),
        ]);
        let paths: Vec<&str> = table.files.iter().map(|f| f.path.as_str()).collect();
        assert_eq!(paths, vec!["a.rs", "z.rs"]);
    }
}
