//! Forward stepper for the mini fixture: the record structs under the
//! adjoint-pairing contract, with one seeded stale field.

pub struct CorrectorRecord {
    pub h: Vec<f64>,
}

pub struct StepRecord {
    pub dt: f64,
    pub u_star: Vec<f64>,
    pub stale_debug: Vec<f64>,
    pub correctors: Vec<CorrectorRecord>,
}

pub fn step(dt: f64, u: &[f64]) -> StepRecord {
    let u_star: Vec<f64> = u.iter().map(|x| x * dt).collect();
    let correctors = vec![CorrectorRecord { h: u_star.clone() }];
    StepRecord { dt, u_star: u_star.clone(), stale_debug: u_star, correctors }
}
