//! Training-engine stand-in for the mini fixture: restores the boundary
//! snapshot and re-steps the solver in one fn — the seeded
//! replay-containment violation.

pub fn replay_episode(solver: &mut Solver, cp: &State, saved: &[f64]) -> f64 {
    solver.mesh.bc_values = saved.to_vec();
    let mut st = cp.clone();
    for _ in 0..4 {
        solver.step(&mut st, None);
    }
    st.energy()
}
