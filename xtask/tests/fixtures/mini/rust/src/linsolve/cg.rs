//! Krylov-style kernel for the mini fixture: seeded allocation and
//! float-determinism violations (plus one justified allocation).

pub fn fresh(n: usize) -> Vec<f64> {
    vec![0.0; n]
}

pub fn iterate(n: usize) -> f64 {
    let mut acc = 0.0;
    loop {
        let v = fresh(n);
        acc += v[0];
        if acc > 3.0 {
            break;
        }
    }
    acc
}

pub fn solve(v: &[f64]) -> f64 {
    let mut acc = 0.0;
    for x in v {
        let doubled: Vec<f64> = v.iter().map(|y| y * x).collect();
        // ALLOC: restart workspace, reached at most once per solve
        let restart = vec![0.0; v.len()];
        acc += doubled[0] + restart[0] + *x;
    }
    let norm: f64 = v.iter().sum::<f64>();
    let single = norm as f32;
    acc + single as f64
}
