//! Assembly kernel for the mini fixture: seeded ExecCtx-flow violations.

pub fn assemble(ctx: &ExecCtx, coeffs: &mut [f64]) {
    let local = ExecCtx::from_env();
    for c in coeffs.iter_mut() {
        *c += 1.0;
    }
    let _ = local;
}
