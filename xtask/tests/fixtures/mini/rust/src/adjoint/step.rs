//! Backward sweep for the mini fixture: consumes every live record field.

pub fn backward_step(rec: &StepRecord) -> f64 {
    let mut acc = rec.dt;
    for cr in &rec.correctors {
        acc += cr.h[0];
    }
    acc + rec.u_star[0]
}
