//! End-to-end snapshot of the `analyze --json` report over the mini
//! fixture tree: locks the CLI surface (exit codes, report shape, rule
//! ordering) that CI's artifact upload and any downstream consumers
//! depend on. The fixture seeds one violation per rule family plus one
//! `// ALLOC:`-justified allocation that must stay quiet.

use std::path::Path;
use std::process::Command;

fn fixture_root() -> std::path::PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("tests").join("fixtures").join("mini")
}

#[test]
fn json_report_matches_snapshot() {
    let out = Command::new(env!("CARGO_BIN_EXE_xtask"))
        .args(["analyze", "--root"])
        .arg(fixture_root())
        .arg("--json")
        .output()
        .expect("the xtask binary is built by the test harness");
    // violations present → nonzero exit, but the JSON report is complete
    assert_eq!(out.status.code(), Some(1), "stderr: {}", String::from_utf8_lossy(&out.stderr));
    let got = String::from_utf8(out.stdout).expect("report is valid UTF-8");
    let want = include_str!("fixtures/mini/expected.json");
    assert_eq!(got, want, "analyze --json drifted from the snapshot");
}

#[test]
fn human_report_lists_violations_and_exits_nonzero() {
    let out = Command::new(env!("CARGO_BIN_EXE_xtask"))
        .args(["analyze", "--root"])
        .arg(fixture_root())
        .output()
        .expect("the xtask binary is built by the test harness");
    assert_eq!(out.status.code(), Some(1));
    let text = String::from_utf8_lossy(&out.stdout);
    for needle in [
        "[adjoint-pairing]",
        "[execctx-construction]",
        "[execctx-unused-param]",
        "[float-reduction]",
        "[lossy-cast]",
        "[precision-boundary]",
        "[hot-loop-alloc]",
        "[replay-containment]",
        "9 violation(s) across 5 files",
    ] {
        assert!(text.contains(needle), "missing `{needle}` in:\n{text}");
    }
}
