"""AOT lowering: jit + lower the Layer-2 entry points to HLO *text* and
write them under artifacts/ with a manifest the Rust runtime consumes.

HLO text (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which xla_extension 0.5.1
rejects; the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: python -m compile.aot --out ../artifacts
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model

jax.config.update("jax_enable_x64", True)

# canonical shapes: the gradient-validation box of paper §4.2 (18x16 there;
# rows must divide the Pallas tile, so we use ny=16, nx=18) and the E5
# corrector resolution
PISO_NY, PISO_NX = 16, 18
CNN_NY, CNN_NX = 24, 48


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_piso_step():
    f64 = jnp.float64
    spec = jax.ShapeDtypeStruct((PISO_NY, PISO_NX), f64)
    scalar = jax.ShapeDtypeStruct((), f64)

    def entry(u, v, p, sx, sy, nu, dt, dx, dy):
        return model.piso_step(u, v, p, sx, sy, nu, dt, dx, dy, tile=8)

    lowered = jax.jit(entry).lower(
        spec, spec, spec, spec, spec, scalar, scalar, scalar, scalar
    )
    return to_hlo_text(lowered), {
        "entry": "piso_step2d",
        "inputs": [
            {"name": n, "shape": [PISO_NY, PISO_NX], "dtype": "f64"}
            for n in ["u", "v", "p", "sx", "sy"]
        ]
        + [{"name": n, "shape": [], "dtype": "f64"} for n in ["nu", "dt", "dx", "dy"]],
        "outputs": [
            {"name": n, "shape": [PISO_NY, PISO_NX], "dtype": "f64"}
            for n in ["u_next", "v_next", "p_next"]
        ],
    }


def lower_stencil():
    f64 = jnp.float64
    xp = jax.ShapeDtypeStruct((PISO_NY + 2, PISO_NX + 2), f64)
    c = jax.ShapeDtypeStruct((PISO_NY, PISO_NX), f64)

    def entry(x_pad, cc, cxm, cxp, cym, cyp):
        from .kernels import stencil

        return (stencil.stencil_apply_2d(x_pad, cc, cxm, cxp, cym, cyp, tile=8),)

    lowered = jax.jit(entry).lower(xp, c, c, c, c, c)
    return to_hlo_text(lowered), {
        "entry": "stencil_matvec2d",
        "inputs": [{"name": "x_pad", "shape": [PISO_NY + 2, PISO_NX + 2], "dtype": "f64"}]
        + [
            {"name": n, "shape": [PISO_NY, PISO_NX], "dtype": "f64"}
            for n in ["cc", "cxm", "cxp", "cym", "cyp"]
        ],
        "outputs": [{"name": "y", "shape": [PISO_NY, PISO_NX], "dtype": "f64"}],
    }


def lower_cnn():
    f32 = jnp.float32
    params = model.cnn_init_params(jax.random.PRNGKey(0), dtype=f32)
    flat, tree = jax.tree_util.tree_flatten(params)
    x = jax.ShapeDtypeStruct((2, CNN_NY, CNN_NX), f32)

    def entry(x, *flat_params):
        p = jax.tree_util.tree_unflatten(tree, list(flat_params))
        return (model.cnn_forward(p, x),)

    specs = [jax.ShapeDtypeStruct(f.shape, f.dtype) for f in flat]
    lowered = jax.jit(entry).lower(x, *specs)
    meta = {
        "entry": "cnn_corrector2d",
        "inputs": [{"name": "x", "shape": [2, CNN_NY, CNN_NX], "dtype": "f32"}]
        + [
            {"name": f"p{i}", "shape": list(f.shape), "dtype": "f32"}
            for i, f in enumerate(flat)
        ],
        "outputs": [{"name": "s", "shape": [2, CNN_NY, CNN_NX], "dtype": "f32"}],
    }
    return to_hlo_text(lowered), meta


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    manifest = {"artifacts": []}
    for name, fn in [
        ("stencil_matvec2d", lower_stencil),
        ("piso_step2d", lower_piso_step),
        ("cnn_corrector2d", lower_cnn),
    ]:
        text, meta = fn()
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta["file"] = f"{name}.hlo.txt"
        manifest["artifacts"].append(meta)
        print(f"wrote {path} ({len(text)} chars)")
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {os.path.join(args.out, 'manifest.json')}")


if __name__ == "__main__":
    main()
