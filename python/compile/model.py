"""Layer-2 JAX model: the full PISO step on a uniform periodic 2D box,
mirroring the Rust discretization exactly (fvm/assemble.rs conventions:
1/J-scaled momentum rows, collocated central fluxes, negated pressure
matrix, two correctors). Lowered once by `aot.py` to HLO text and executed
from the Rust hot path via PJRT — Python is never on the request path.

Also defines the corrector-CNN forward (periodic multi-block convolution
degenerates to wrap padding on a single periodic block).
"""

import functools

import jax
import jax.numpy as jnp

from .kernels import solve, stencil

jax.config.update("jax_enable_x64", True)


def piso_coefficients(u, v, nu, dt, dx, dy):
    """Stencil coefficients of the advection-diffusion matrix C (1/J-scaled).

    u, v: (ny, nx) velocity components; returns (cc, cxm, cxp, cym, cyp).
    """
    jac = dx * dy
    a00 = jac / (dx * dx)  # alpha_00 = J T00^2
    a11 = jac / (dy * dy)
    ux = jac * u / dx  # contravariant U^0
    uy = jac * v / dy  # contravariant U^1
    uf_xp = 0.5 * (ux + jnp.roll(ux, -1, axis=1))
    uf_xm = 0.5 * (ux + jnp.roll(ux, 1, axis=1))
    uf_yp = 0.5 * (uy + jnp.roll(uy, -1, axis=0))
    uf_ym = 0.5 * (uy + jnp.roll(uy, 1, axis=0))
    inv_j = 1.0 / jac
    dnu_x = a00 * nu * inv_j
    dnu_y = a11 * nu * inv_j
    cxp = 0.5 * uf_xp * inv_j - dnu_x
    cxm = -0.5 * uf_xm * inv_j - dnu_x
    cyp = 0.5 * uf_yp * inv_j - dnu_y
    cym = -0.5 * uf_ym * inv_j - dnu_y
    cc = (
        1.0 / dt
        + 0.5 * (uf_xp - uf_xm) * inv_j
        + 0.5 * (uf_yp - uf_ym) * inv_j
        + 2.0 * (dnu_x + dnu_y)
    )
    return cc, cxm, cxp, cym, cyp


def pressure_coefficients(a_inv, dx, dy):
    """Stencil coefficients of M = -P (negated pressure Laplacian)."""
    jac = dx * dy
    a00 = jac / (dx * dx)
    a11 = jac / (dy * dy)
    m_xp = -0.5 * a00 * (a_inv + jnp.roll(a_inv, -1, axis=1))
    m_xm = -0.5 * a00 * (a_inv + jnp.roll(a_inv, 1, axis=1))
    m_yp = -0.5 * a11 * (a_inv + jnp.roll(a_inv, -1, axis=0))
    m_ym = -0.5 * a11 * (a_inv + jnp.roll(a_inv, 1, axis=0))
    mc = -(m_xp + m_xm + m_yp + m_ym)
    return mc, m_xm, m_xp, m_ym, m_yp


def grad_p(p, dx, dy):
    """Collocated central pressure gradient (A.20) on a periodic box."""
    gx = (jnp.roll(p, -1, axis=1) - jnp.roll(p, 1, axis=1)) / (2.0 * dx)
    gy = (jnp.roll(p, -1, axis=0) - jnp.roll(p, 1, axis=0)) / (2.0 * dy)
    return gx, gy

def divergence(hx, hy, dx, dy):
    """Volume-form divergence with collocated central interpolation (A.18)."""
    jac = dx * dy
    ux = jac * hx / dx
    uy = jac * hy / dy
    return 0.5 * (jnp.roll(ux, -1, axis=1) - jnp.roll(ux, 1, axis=1)) + 0.5 * (
        jnp.roll(uy, -1, axis=0) - jnp.roll(uy, 1, axis=0)
    )


@functools.partial(
    jax.jit, static_argnames=("adv_iters", "p_iters", "n_correctors", "tile")
)
def piso_step(
    u, v, p, sx, sy, nu, dt, dx, dy, adv_iters=60, p_iters=120, n_correctors=2, tile=8
):
    """One PISO step on a uniform fully-periodic 2D box.

    Mirrors `PisoSolver::step` for this mesh class; the stencil matvecs run
    through the Layer-1 Pallas kernel.
    """
    cc, cxm, cxp, cym, cyp = piso_coefficients(u, v, nu, dt, dx, dy)
    apply_c = solve.make_periodic_stencil_apply(cc, cxm, cxp, cym, cyp, tile=tile)

    gpx, gpy = grad_p(p, dx, dy)
    rhs_base_x = u / dt + sx
    rhs_base_y = v / dt + sy
    u_star = solve.bicgstab(apply_c, rhs_base_x - gpx, u, adv_iters)
    v_star = solve.bicgstab(apply_c, rhs_base_y - gpy, v, adv_iters)

    a_inv = 1.0 / cc
    mc, m_xm, m_xp, m_ym, m_yp = pressure_coefficients(a_inv, dx, dy)
    apply_m = solve.make_periodic_stencil_apply(mc, m_xm, m_xp, m_ym, m_yp, tile=tile)
    apply_h = solve.make_periodic_stencil_apply(
        jnp.zeros_like(cc), cxm, cxp, cym, cyp, tile=tile
    )

    u_cur, v_cur, p_cur = u_star, v_star, p
    for _ in range(n_correctors):
        hx = a_inv * (rhs_base_x - apply_h(u_cur))
        hy = a_inv * (rhs_base_y - apply_h(v_cur))
        div = divergence(hx, hy, dx, dy)
        p_cur = solve.cg(apply_m, -div, p_cur, p_iters, project_nullspace=True)
        gx, gy = grad_p(p_cur, dx, dy)
        u_cur = hx - a_inv * gx
        v_cur = hy - a_inv * gy
    return u_cur, v_cur, p_cur


# ---------------------------------------------------------------------------
# Corrector CNN (paper §5.1 architecture, periodic padding)
# ---------------------------------------------------------------------------

CNN_LAYERS = [(16, 7), (32, 5), (64, 5), (64, 3), (64, 3), (64, 1), (2, 1)]


def cnn_init_params(key, cin=2, layers=CNN_LAYERS, dtype=jnp.float32):
    """He-initialized parameters for the 7-layer corrector CNN."""
    params = []
    prev = cin
    for cout, k in layers:
        key, sub = jax.random.split(key)
        fan_in = prev * k * k
        w = jax.random.normal(sub, (cout, prev, k, k), dtype) * jnp.sqrt(2.0 / fan_in)
        b = jnp.zeros((cout,), dtype)
        params.append((w, b))
        prev = cout
    return params


def cnn_forward(params, x):
    """x: (cin, ny, nx) -> (2, ny, nx); periodic padding, ReLU except last."""
    h = x
    for li, (w, b) in enumerate(params):
        k = w.shape[-1]
        pad = k // 2
        hp = jnp.pad(h, ((0, 0), (pad, pad), (pad, pad)), mode="wrap")
        h = jax.lax.conv_general_dilated(
            hp[None], w, (1, 1), "VALID", dimension_numbers=("NCHW", "OIHW", "NCHW")
        )[0] + b[:, None, None]
        if li + 1 < len(params):
            h = jax.nn.relu(h)
    return h
