"""Pure-jnp oracles for the Pallas kernels — the correctness contract.

Every kernel in this package must match its reference here to float
round-off; pytest (with hypothesis shape/dtype sweeps) enforces it.
"""

import jax.numpy as jnp


def stencil_apply_2d_ref(x_pad, cc, cxm, cxp, cym, cyp):
    """Reference 5-point stencil on a ghost-padded field (pure jnp)."""
    center = x_pad[1:-1, 1:-1]
    xm = x_pad[1:-1, :-2]
    xp_ = x_pad[1:-1, 2:]
    ym = x_pad[:-2, 1:-1]
    yp = x_pad[2:, 1:-1]
    return cc * center + cxm * xm + cxp * xp_ + cym * ym + cyp * yp


def cg_ref(apply_a, b, x0, iters):
    """Textbook CG with a fixed iteration count (matches kernels.solve.cg)."""
    x = x0
    r = b - apply_a(x)
    p = r
    rs = jnp.vdot(r, r)
    for _ in range(iters):
        ap = apply_a(p)
        alpha = rs / jnp.maximum(jnp.vdot(p, ap), 1e-300)
        x = x + alpha * p
        r = r - alpha * ap
        rs_new = jnp.vdot(r, r)
        p = r + (rs_new / jnp.maximum(rs, 1e-300)) * p
        rs = rs_new
    return x
