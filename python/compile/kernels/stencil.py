"""Layer-1 Pallas kernels: structured 5-point (2D) stencil apply.

This is the compute hot-spot of the PISO solver: every BiCGStab/CG
iteration applies the advection-diffusion matrix C or the pressure
Laplacian M, both of which are 5-point stencils on a structured block.
The kernel consumes *ghost-padded* inputs (the L2 model fills ghosts
according to the boundary conditions — periodic wrap, Dirichlet, or
Neumann — with cheap jnp ops), so the kernel itself is a pure interior
stencil and tiles cleanly.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the row-tile BlockSpec
with a +2 halo expresses the HBM->VMEM schedule that the paper's CUDA
version expresses with threadblock shared-memory tiles; the arithmetic is
VPU element-wise work (no MXU). interpret=True everywhere on CPU — real
TPU lowering would emit a Mosaic custom-call the CPU PJRT cannot run.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _make_stencil_tile_kernel(tile):
    """Kernel factory: each grid step processes `tile` rows, loading a
    (tile+2)-row halo window from the ghost-padded input with a dynamic
    slice (Pallas Blocked index_maps address whole blocks, so the
    overlapping halo window is expressed as an in-kernel dynamic load)."""

    def kernel(xp_ref, cc_ref, cxm_ref, cxp_ref, cym_ref, cyp_ref, o_ref):
        j = pl.program_id(0)
        xp = pl.load(xp_ref, (pl.dslice(j * tile, tile + 2), slice(None)))
        center = xp[1:-1, 1:-1]
        xm = xp[1:-1, :-2]
        xx = xp[1:-1, 2:]
        ym = xp[:-2, 1:-1]
        yp = xp[2:, 1:-1]
        o_ref[...] = (
            cc_ref[...] * center
            + cxm_ref[...] * xm
            + cxp_ref[...] * xx
            + cym_ref[...] * ym
            + cyp_ref[...] * yp
        )

    return kernel


@functools.partial(jax.jit, static_argnames=("tile",))
def stencil_apply_2d(x_pad, cc, cxm, cxp, cym, cyp, tile=8):
    """y[j,i] = cc*x + cxm*x[.,i-1] + cxp*x[.,i+1] + cym*x[j-1,.] + cyp*x[j+1,.]

    x_pad: (ny+2, nx+2) ghost-padded field; coefficients: (ny, nx).
    Rows are processed in `tile`-row blocks with a one-row halo, the
    classic overlapping-window BlockSpec pattern.
    """
    ny, nx = cc.shape
    assert x_pad.shape == (ny + 2, nx + 2)
    assert ny % tile == 0, f"ny={ny} must be divisible by tile={tile}"
    grid = (ny // tile,)
    coeff_spec = pl.BlockSpec((tile, nx), lambda j: (j, 0))  # block units
    return pl.pallas_call(
        _make_stencil_tile_kernel(tile),
        grid=grid,
        in_specs=[
            # full padded field resident; the kernel slices its halo window
            pl.BlockSpec((ny + 2, nx + 2), lambda j: (0, 0)),
            coeff_spec,
            coeff_spec,
            coeff_spec,
            coeff_spec,
            coeff_spec,
        ],
        out_specs=pl.BlockSpec((tile, nx), lambda j: (j, 0)),
        out_shape=jax.ShapeDtypeStruct((ny, nx), cc.dtype),
        interpret=True,
    )(x_pad, cc, cxm, cxp, cym, cyp)


def pad_periodic(x):
    """Ghost-pad a (ny, nx) field with periodic wrap -> (ny+2, nx+2)."""
    return jnp.pad(x, 1, mode="wrap")


def pad_neumann(x):
    """Ghost-pad with zero-gradient (edge replicate)."""
    return jnp.pad(x, 1, mode="edge")


def pad_zero(x):
    """Ghost-pad with zeros (Dirichlet handled via RHS)."""
    return jnp.pad(x, 1, mode="constant")
