"""Fixed-iteration Krylov solvers built on the Pallas stencil kernel.

These are the L2 building blocks for the AOT PISO step: a CG for the
(symmetric) pressure system and a BiCGStab for the advection-diffusion
system, both with a compile-time iteration count (`lax.fori_loop`) so the
whole solve lowers into one HLO module with no host round-trips.
"""

import jax
import jax.numpy as jnp
from jax import lax

EPS = 1e-300


def _safe_div(num, den):
    """num/den, but 0 when the denominator has collapsed to round-off —
    the standard Krylov breakdown guard (sign-preserving, unlike max)."""
    ok = jnp.abs(den) > 1e-290
    return jnp.where(ok, num / jnp.where(ok, den, 1.0), 0.0)


def cg(apply_a, b, x0, iters, project_nullspace=False):
    """Fixed-iteration conjugate gradient; optionally keeps iterates
    mean-free (constant-nullspace deflation for periodic Laplacians)."""

    def proj(v):
        return v - jnp.mean(v) if project_nullspace else v

    b = proj(b)

    bnorm2 = jnp.vdot(b, b)

    def body(_, carry):
        x, r, p, rs = carry
        done = rs <= 1e-28 * (bnorm2 + 1e-30)
        ap = proj(apply_a(p))
        alpha = _safe_div(rs, jnp.vdot(p, ap))
        x_new = x + alpha * p
        r_new = r - alpha * ap
        rs_new = jnp.vdot(r_new, r_new)
        beta = _safe_div(rs_new, rs)
        p_new = r_new + beta * p
        # freeze once converged to round-off (prevents breakdown noise)
        keep = lambda old, new: jnp.where(done, old, new)
        return (keep(x, x_new), keep(r, r_new), keep(p, p_new), keep(rs, rs_new))

    x0 = proj(x0)
    r0 = proj(b - apply_a(x0))
    x, _, _, _ = lax.fori_loop(0, iters, body, (x0, r0, r0, jnp.vdot(r0, r0)))
    return proj(x)


def bicgstab(apply_a, b, x0, iters):
    """Fixed-iteration BiCGStab (unpreconditioned)."""

    bnorm2 = jnp.vdot(b, b)

    def body(_, carry):
        x, r, r0, p, v, rho, alpha, omega = carry
        done = jnp.vdot(r, r) <= 1e-28 * (bnorm2 + 1e-30)
        rho_new = jnp.vdot(r0, r)
        beta = _safe_div(rho_new, rho) * _safe_div(alpha, omega)
        p_new = r + beta * (p - omega * v)
        v_new = apply_a(p_new)
        alpha_new = _safe_div(rho_new, jnp.vdot(r0, v_new))
        s = r - alpha_new * v_new
        t = apply_a(s)
        omega_new = _safe_div(jnp.vdot(t, s), jnp.vdot(t, t))
        x_new = x + alpha_new * p_new + omega_new * s
        r_new = s - omega_new * t
        keep = lambda old, new: jnp.where(done, old, new)
        return (
            keep(x, x_new), keep(r, r_new), r0, keep(p, p_new), keep(v, v_new),
            keep(rho, rho_new), keep(alpha, alpha_new), keep(omega, omega_new),
        )

    r0 = b - apply_a(x0)
    init = (x0, r0, r0, jnp.zeros_like(b), jnp.zeros_like(b), jnp.asarray(1.0, b.dtype),
            jnp.asarray(1.0, b.dtype), jnp.asarray(1.0, b.dtype))
    x, *_ = lax.fori_loop(0, iters, body, init)
    return x


def make_periodic_stencil_apply(cc, cxm, cxp, cym, cyp, tile=8):
    """Stencil matvec closure over a periodic 2D box using the L1 kernel."""
    from . import stencil

    def apply_a(x):
        return stencil.stencil_apply_2d(
            stencil.pad_periodic(x), cc, cxm, cxp, cym, cyp, tile=tile
        )

    return apply_a
