"""L2 model correctness: the JAX PISO step's physical invariants (they are
cross-checked numerically against the Rust native engine by the Rust
runtime tests), plus CNN shape/architecture checks."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model

jax.config.update("jax_enable_x64", True)

NY, NX = 16, 18
DX, DY = 1.0 / NX, 1.0 / NY


def taylor_green(ny, nx):
    y = (jnp.arange(ny) + 0.5) * DY
    x = (jnp.arange(nx) + 0.5) * DX
    xx, yy = jnp.meshgrid(x, y)
    tau = 2.0 * jnp.pi
    u = jnp.sin(tau * xx) * jnp.cos(tau * yy)
    v = -jnp.cos(tau * xx) * jnp.sin(tau * yy)
    return u, v


def test_piso_step_keeps_divergence_small():
    u, v = taylor_green(NY, NX)
    p = jnp.zeros((NY, NX))
    s = jnp.zeros((NY, NX))
    un, vn, pn = model.piso_step(u, v, p, s, s, 0.02, 0.01, DX, DY)
    div = model.divergence(un, vn, DX, DY) / (DX * DY)
    assert float(jnp.max(jnp.abs(div))) < 0.2
    assert np.isfinite(np.asarray(un)).all()


def test_piso_step_zero_velocity_fixed_point():
    z = jnp.zeros((NY, NX))
    un, vn, pn = model.piso_step(z, z, z, z, z, 0.02, 0.01, DX, DY)
    np.testing.assert_allclose(np.asarray(un), 0.0, atol=1e-12)
    np.testing.assert_allclose(np.asarray(vn), 0.0, atol=1e-12)


def test_piso_step_uniform_flow_is_invariant():
    # uniform velocity on a periodic box is an exact steady solution
    u = jnp.full((NY, NX), 0.7)
    v = jnp.full((NY, NX), -0.3)
    z = jnp.zeros((NY, NX))
    un, vn, _ = model.piso_step(u, v, z, z, z, 0.02, 0.01, DX, DY)
    np.testing.assert_allclose(np.asarray(un), 0.7, rtol=1e-9)
    np.testing.assert_allclose(np.asarray(vn), -0.3, rtol=1e-9)


def test_piso_viscous_decay_rate():
    # Taylor-Green kinetic energy decays as exp(-4 nu tau^2 t) (square box);
    # here the box is 1x1 with tau=2pi
    u, v = taylor_green(NY, NX)
    z = jnp.zeros((NY, NX))
    nu, dt, nsteps = 0.05, 2e-3, 10
    uc, vc, pc = u, v, z
    for _ in range(nsteps):
        uc, vc, pc = model.piso_step(uc, vc, pc, z, z, nu, dt, DX, DY)
    e0 = float(jnp.sum(u**2 + v**2))
    e1 = float(jnp.sum(uc**2 + vc**2))
    tau = 2.0 * jnp.pi
    expect = float(jnp.exp(-4.0 * nu * tau * tau * nu_time(dt, nsteps)))
    assert abs(e1 / e0 - expect) < 0.08 * expect, (e1 / e0, expect)


def nu_time(dt, n):
    return dt * n


def test_source_term_accelerates_flow():
    z = jnp.zeros((NY, NX))
    s = jnp.full((NY, NX), 1.0)
    un, vn, _ = model.piso_step(z, z, z, s, z, 0.02, 0.05, DX, DY)
    # du/dt = S => u ~ dt * S
    np.testing.assert_allclose(np.asarray(un), 0.05, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(vn), 0.0, atol=1e-10)


def test_cnn_forward_shapes_and_param_count():
    params = model.cnn_init_params(jax.random.PRNGKey(0))
    x = jnp.zeros((2, 24, 48), jnp.float32)
    y = model.cnn_forward(params, x)
    assert y.shape == (2, 24, 48)
    nparams = sum(int(np.prod(w.shape)) + int(np.prod(b.shape)) for w, b in params)
    # paper §5.1: 7 layers, 16/32/64/64/64/64/2 filters, kernels 7/5/5/3/3/1/1
    # (the paper quotes 144750 params for its exact configuration)
    assert nparams > 100_000, nparams


def test_cnn_translation_equivariance_periodic():
    # periodic padding => translating the input translates the output
    params = model.cnn_init_params(jax.random.PRNGKey(1))
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((2, 24, 48)), jnp.float32)
    y = model.cnn_forward(params, x)
    xs = jnp.roll(x, (3, 5), axis=(1, 2))
    ys = model.cnn_forward(params, xs)
    np.testing.assert_allclose(
        np.asarray(ys), np.asarray(jnp.roll(y, (3, 5), axis=(1, 2))), rtol=2e-4, atol=2e-4
    )
