"""L1 kernel correctness: the Pallas stencil against the pure-jnp oracle,
with hypothesis sweeping shapes and dtypes (the session's CORE correctness
signal for the kernel layer)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref, solve, stencil

jax.config.update("jax_enable_x64", True)


def rand(rng, shape, dtype):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@settings(max_examples=25, deadline=None)
@given(
    tiles=st.integers(min_value=1, max_value=4),
    tile=st.sampled_from([4, 8]),
    nx=st.integers(min_value=3, max_value=33),
    dtype=st.sampled_from([np.float32, np.float64]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_stencil_matches_ref_hypothesis(tiles, tile, nx, dtype, seed):
    ny = tiles * tile
    rng = np.random.default_rng(seed)
    x_pad = rand(rng, (ny + 2, nx + 2), dtype)
    coeffs = [rand(rng, (ny, nx), dtype) for _ in range(5)]
    got = stencil.stencil_apply_2d(x_pad, *coeffs, tile=tile)
    want = ref.stencil_apply_2d_ref(x_pad, *coeffs)
    tol = 1e-5 if dtype == np.float32 else 1e-12
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=tol, atol=tol)


def test_stencil_identity_kernel():
    ny, nx = 8, 6
    x = jnp.arange(ny * nx, dtype=jnp.float64).reshape(ny, nx)
    one = jnp.ones((ny, nx))
    zero = jnp.zeros((ny, nx))
    y = stencil.stencil_apply_2d(stencil.pad_periodic(x), one, zero, zero, zero, zero)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x))


def test_stencil_periodic_shift():
    ny, nx = 8, 5
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.standard_normal((ny, nx)))
    one = jnp.ones((ny, nx))
    zero = jnp.zeros((ny, nx))
    # pure +x neighbor pick == roll by -1 along axis 1
    y = stencil.stencil_apply_2d(stencil.pad_periodic(x), zero, zero, one, zero, zero)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.roll(x, -1, axis=1)))


def test_cg_solves_periodic_poisson():
    ny, nx = 16, 18
    # M = negated periodic Laplacian (SPD on the mean-free subspace)
    one = jnp.ones((ny, nx), jnp.float64)
    apply_m = solve.make_periodic_stencil_apply(4.0 * one, -one, -one, -one, -one)
    rng = np.random.default_rng(1)
    b = jnp.asarray(rng.standard_normal((ny, nx)))
    b = b - jnp.mean(b)
    x = solve.cg(apply_m, b, jnp.zeros_like(b), 300, project_nullspace=True)
    np.testing.assert_allclose(np.asarray(apply_m(x) - b), 0.0, atol=1e-8)


def test_cg_matches_ref():
    ny, nx = 8, 9
    one = jnp.ones((ny, nx), jnp.float64)
    apply_m = solve.make_periodic_stencil_apply(5.0 * one, -one, -one, -one, -one)
    rng = np.random.default_rng(2)
    b = jnp.asarray(rng.standard_normal((ny, nx)))
    x0 = jnp.zeros_like(b)
    got = solve.cg(apply_m, b, x0, 25)
    want = ref.cg_ref(apply_m, b, x0, 25)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-10)


def test_bicgstab_solves_nonsymmetric():
    ny, nx = 8, 8
    one = jnp.ones((ny, nx), jnp.float64)
    # asymmetric advection-diffusion-like stencil, diagonally dominant
    apply_a = solve.make_periodic_stencil_apply(
        5.0 * one, -1.5 * one, -0.5 * one, -1.2 * one, -0.8 * one
    )
    rng = np.random.default_rng(3)
    xs = jnp.asarray(rng.standard_normal((ny, nx)))
    b = apply_a(xs)
    x = solve.bicgstab(apply_a, b, jnp.zeros_like(b), 200)
    np.testing.assert_allclose(np.asarray(x), np.asarray(xs), rtol=1e-7, atol=1e-8)


def test_stencil_rejects_non_divisible_tile():
    ny, nx = 10, 8  # 10 % 8 != 0
    x = jnp.zeros((ny + 2, nx + 2))
    c = jnp.zeros((ny, nx))
    with pytest.raises(AssertionError):
        stencil.stencil_apply_2d(x, c, c, c, c, c, tile=8)


def test_pad_helpers_shapes_and_values():
    x = jnp.arange(12.0).reshape(3, 4)
    pw = stencil.pad_periodic(x)
    pe = stencil.pad_neumann(x)
    pz = stencil.pad_zero(x)
    assert pw.shape == pe.shape == pz.shape == (5, 6)
    assert float(pw[0, 1]) == float(x[-1, 0])  # wrap
    assert float(pe[0, 1]) == float(x[0, 0])   # replicate
    assert float(pz[0, 1]) == 0.0              # zero


def test_bicgstab_handles_exact_initial_solution():
    ny, nx = 8, 8
    one = jnp.ones((ny, nx), jnp.float64)
    apply_a = solve.make_periodic_stencil_apply(5.0 * one, -one, -one, -one, -one)
    rng = np.random.default_rng(4)
    xs = jnp.asarray(rng.standard_normal((ny, nx)))
    b = apply_a(xs)
    x = solve.bicgstab(apply_a, b, xs, 50)  # x0 is already the solution
    assert np.isfinite(np.asarray(x)).all()
    np.testing.assert_allclose(np.asarray(x), np.asarray(xs), rtol=1e-10)
